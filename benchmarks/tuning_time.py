"""Table 1: tuning time.  Wall-clock per trial of the search loop across
representative workloads (the paper compares MetaSchedule vs Ansor
minutes at equal trial budgets).

This driver additionally compares measurement backends from the runner
registry at an equal trial count — by default the serial in-process
``local`` runner vs ``cached+pool`` (process-pool parallel measurement
behind a trace-hash cache) — and reports the wall-clock speedup and the
cache-hit rate.  ``--smoke`` runs a single tiny workload for CI.
"""

from __future__ import annotations

import argparse
import os
from typing import Dict, List, Sequence

from repro.search.evolutionary import SearchConfig
from repro.search.measure import create_runner
from repro.search.tune import tune_workload

WORKLOADS = [
    ("gmm", dict(n=128, m=128, k=128), True),
    ("fused_dense", dict(m=128, n=512, k=256), True),
    ("sfm", dict(m=256, n=256), False),
]

SMOKE_WORKLOADS = [("gmm", dict(n=64, m=64, k=64), False)]

DEFAULT_RUNNERS = ("local", "cached+pool")


def run(
    csv: bool = True,
    smoke: bool = False,
    runner_specs: Sequence[str] = DEFAULT_RUNNERS,
    backend: str = None,
) -> List[Dict]:
    trials = int(os.environ.get("REPRO_BENCH_TRIALS", "6" if smoke else "16"))
    workloads = SMOKE_WORKLOADS if smoke else WORKLOADS
    cfg = SearchConfig(
        max_trials=trials, init_random=max(trials // 4, 4),
        population=max(trials // 2, 8), measure_per_round=max(trials // 4, 4),
    )
    out = []
    # one runner instance per spec, shared across workloads — the same
    # lifetime TaskScheduler gives it, so pool startup amortizes and the
    # cache can dedup across rounds.  All build through the selected
    # lowering backend (--backend / REPRO_BACKEND).
    runners = {
        spec: create_runner(spec, backend=backend) for spec in runner_specs
    }
    prev_stats: Dict[str, tuple] = {}
    try:
        _run_workloads(workloads, runner_specs, runners, cfg, prev_stats, out, csv)
    finally:
        for r in runners.values():
            r.close()
    return out


def _run_workloads(workloads, runner_specs, runners, cfg, prev_stats, out, csv):
    for name, kwargs, mxu in workloads:
        per_runner: Dict[str, Dict] = {}
        for spec in runner_specs:
            res = tune_workload(
                name, kwargs, use_mxu=mxu, config=cfg, runner=runners[spec]
            )
            # stats() is cumulative over the runner's life: report deltas
            prev = prev_stats.setdefault(spec, (0, 0))
            hits = res.cache_hits - prev[0]
            misses = res.cache_misses - prev[1]
            prev_stats[spec] = (res.cache_hits, res.cache_misses)
            hit_rate = hits / max(hits + misses, 1)
            row = {
                "workload": name,
                "runner": spec,
                "trials": res.trials,
                "tuning_s": res.tuning_time_s,
                "s_per_trial": res.tuning_time_s / max(res.trials, 1),
                "best_us": res.best_latency_s * 1e6,
                "failures": res.measure_failures,
                "cache_hits": hits,
                "cache_misses": misses,
                "cache_hit_rate": hit_rate,
            }
            per_runner[spec] = row
            out.append(row)
            if csv:
                print(
                    f"tuning_time/{name}/{spec},{row['s_per_trial']*1e6:.0f},"
                    f"trials={row['trials']};total_s={row['tuning_s']:.1f};"
                    f"failures={row['failures']};cache_hit_rate={hit_rate:.2f}"
                )
        if csv and len(per_runner) >= 2:
            specs = list(per_runner)
            base, cand = per_runner[specs[0]], per_runner[specs[-1]]
            speedup = base["tuning_s"] / max(cand["tuning_s"], 1e-9)
            print(
                f"tuning_time/{name}/speedup,{speedup:.2f},"
                f"{specs[0]}_s={base['tuning_s']:.1f};{specs[-1]}_s={cand['tuning_s']:.1f}"
            )


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--smoke", action="store_true",
        help="single tiny workload + small trial budget (CI)",
    )
    ap.add_argument(
        "--runners", default=",".join(DEFAULT_RUNNERS),
        help="comma-separated runner registry specs to compare",
    )
    ap.add_argument(
        "--backend", default=None,
        help="lowering-backend spec (jnp, pallas, ...); default "
             "REPRO_BACKEND env or jnp",
    )
    args = ap.parse_args(argv)
    run(
        smoke=args.smoke,
        runner_specs=[s for s in args.runners.split(",") if s],
        backend=args.backend,
    )


if __name__ == "__main__":
    main()
