"""Table 1: tuning time.  Wall-clock per trial and trials/sec of the
search loop across representative workloads (the paper compares
MetaSchedule vs Ansor minutes at equal trial budgets)."""

from __future__ import annotations

import os
from typing import Dict, List

from repro.search.evolutionary import SearchConfig
from repro.search.tune import tune_workload

WORKLOADS = [
    ("gmm", dict(n=128, m=128, k=128), True),
    ("fused_dense", dict(m=128, n=512, k=256), True),
    ("sfm", dict(m=256, n=256), False),
]


def run(csv: bool = True) -> List[Dict]:
    trials = int(os.environ.get("REPRO_BENCH_TRIALS", "16"))
    cfg = SearchConfig(
        max_trials=trials, init_random=max(trials // 4, 4),
        population=max(trials // 2, 8), measure_per_round=max(trials // 4, 4),
    )
    out = []
    for name, kwargs, mxu in WORKLOADS:
        res = tune_workload(name, kwargs, use_mxu=mxu, config=cfg)
        row = {
            "workload": name,
            "trials": res.trials,
            "tuning_s": res.tuning_time_s,
            "s_per_trial": res.tuning_time_s / max(res.trials, 1),
        }
        out.append(row)
        if csv:
            print(
                f"tuning_time/{name},{row['s_per_trial']*1e6:.0f},"
                f"trials={row['trials']};total_s={row['tuning_s']:.1f}"
            )
    return out


if __name__ == "__main__":
    run()
