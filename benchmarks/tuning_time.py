"""Table 1: tuning time.  Wall-clock per trial of the search loop across
representative workloads (the paper compares MetaSchedule vs Ansor
minutes at equal trial budgets).

This driver additionally compares measurement backends from the runner
registry at an equal trial count — by default the serial in-process
``local`` runner vs ``cached+pool`` (process-pool parallel measurement
behind a trace-hash cache) — and reports the wall-clock speedup and the
cache-hit rate.  ``--smoke`` runs a single tiny workload for CI.

It also runs the learned-search transfer comparison (README "Learned
search"): a cold tune persists its cost model + sampling distributions,
then a *warm* tune on a fresh database — learned state only, no record
leakage — must reach the cold run's best latency in at most 60% of the
cold run's measured trials.  Results land in ``BENCH_tuning_time.json``
(``--json-out``), which CI uploads as an artifact.
"""

from __future__ import annotations

import argparse
import json
import os
import tempfile
from typing import Dict, List, Optional, Sequence

from repro.search.cost_model import GBDTCostModel
from repro.search.database import Database, sidecar_path
from repro.search.distributions import DecisionDistributions
from repro.search.evolutionary import SearchConfig
from repro.search.measure import create_runner
from repro.search.tune import TuneConfig, tune_workload

WORKLOADS = [
    ("gmm", dict(n=128, m=128, k=128), True),
    ("fused_dense", dict(m=128, n=512, k=256), True),
    ("sfm", dict(m=256, n=256), False),
]

SMOKE_WORKLOADS = [("gmm", dict(n=64, m=64, k=64), False)]

DEFAULT_RUNNERS = ("local", "cached+pool")


def _bench_config(trials: int) -> SearchConfig:
    return SearchConfig(
        max_trials=trials, init_random=max(trials // 4, 4),
        population=max(trials // 2, 8), measure_per_round=max(trials // 4, 4),
    )


def run(
    csv: bool = True,
    smoke: bool = False,
    runner_specs: Sequence[str] = DEFAULT_RUNNERS,
    backend: str = None,
) -> List[Dict]:
    trials = int(os.environ.get("REPRO_BENCH_TRIALS", "6" if smoke else "16"))
    workloads = SMOKE_WORKLOADS if smoke else WORKLOADS
    cfg = _bench_config(trials)
    out = []
    # one runner instance per spec, shared across workloads — the same
    # lifetime TaskScheduler gives it, so pool startup amortizes and the
    # cache can dedup across rounds.  All build through the selected
    # lowering backend (--backend / REPRO_BACKEND).
    runners = {
        spec: create_runner(spec, backend=backend) for spec in runner_specs
    }
    prev_stats: Dict[str, tuple] = {}
    try:
        _run_workloads(workloads, runner_specs, runners, cfg, prev_stats, out, csv)
    finally:
        for r in runners.values():
            r.close()
    return out


def _run_workloads(workloads, runner_specs, runners, cfg, prev_stats, out, csv):
    for name, kwargs, mxu in workloads:
        per_runner: Dict[str, Dict] = {}
        for spec in runner_specs:
            res = tune_workload(
                name, kwargs,
                config=TuneConfig(
                    search=cfg, use_mxu=mxu, runner_spec=runners[spec]
                ),
            )
            # stats() is cumulative over the runner's life: report deltas
            prev = prev_stats.setdefault(spec, (0, 0))
            hits = res.cache_hits - prev[0]
            misses = res.cache_misses - prev[1]
            prev_stats[spec] = (res.cache_hits, res.cache_misses)
            hit_rate = hits / max(hits + misses, 1)
            row = {
                "workload": name,
                "runner": spec,
                "trials": res.trials,
                "tuning_s": res.tuning_time_s,
                "s_per_trial": res.tuning_time_s / max(res.trials, 1),
                "best_us": res.best_latency_s * 1e6,
                "failures": res.measure_failures,
                "cache_hits": hits,
                "cache_misses": misses,
                "cache_hit_rate": hit_rate,
            }
            per_runner[spec] = row
            out.append(row)
            if csv:
                print(
                    f"tuning_time/{name}/{spec},{row['s_per_trial']*1e6:.0f},"
                    f"trials={row['trials']};total_s={row['tuning_s']:.1f};"
                    f"failures={row['failures']};cache_hit_rate={hit_rate:.2f}"
                )
        if csv and len(per_runner) >= 2:
            specs = list(per_runner)
            base, cand = per_runner[specs[0]], per_runner[specs[-1]]
            speedup = base["tuning_s"] / max(cand["tuning_s"], 1e-9)
            print(
                f"tuning_time/{name}/speedup,{speedup:.2f},"
                f"{specs[0]}_s={base['tuning_s']:.1f};{specs[-1]}_s={cand['tuning_s']:.1f}"
            )


def warm_start_comparison(
    smoke: bool = False, backend: str = None, csv: bool = True
) -> Optional[Dict]:
    """Cold-vs-warm tuning of one workload through persisted learned state.

    The cold run tunes with a fresh file-backed database, persisting its
    cost model and sampling distributions as sidecars.  The warm run gets a
    *fresh, empty* database plus only the loaded sidecar objects — so any
    speedup comes from transferred learned state, never from replaying
    database records.  The claim checked: the warm run reaches the cold
    run's best latency (within ``REPRO_BENCH_TOLERANCE``, default 1.10) in
    at most 60% of the cold run's measured trials.
    """
    trials = int(os.environ.get("REPRO_BENCH_TRIALS", "6" if smoke else "16"))
    tol = float(os.environ.get("REPRO_BENCH_TOLERANCE", "1.10"))
    name, kwargs, mxu = (SMOKE_WORKLOADS if smoke else WORKLOADS)[0]
    cfg = _bench_config(trials)
    d = tempfile.mkdtemp(prefix="repro_warm_bench_")
    cold_db = Database(os.path.join(d, "cold_db.json"))
    cold = tune_workload(
        name, kwargs, database=cold_db,
        config=TuneConfig(search=cfg, use_mxu=mxu, backend=backend),
    )
    model_path = sidecar_path(cold_db.path, "model")
    dists_path = sidecar_path(cold_db.path, "dists")
    if not (os.path.exists(model_path) and os.path.exists(dists_path)):
        if csv:
            print(f"tuning_time/{name}/warm_start,skipped,no_sidecars")
        return None
    warm_cfg = _bench_config(trials)
    warm_cfg.seed = cfg.seed + 1  # transfer, not a replay of the cold rng
    warm = tune_workload(
        name, kwargs,
        database=Database(os.path.join(d, "warm_db.json")),
        config=TuneConfig(
            search=warm_cfg, use_mxu=mxu, backend=backend,
            cost_model=GBDTCostModel.load(model_path),
            distributions=DecisionDistributions.load(dists_path),
        ),
    )
    target = cold.best_latency_s * tol
    warm_trials = warm.trials_to(target)
    row = {
        "workload": name,
        "trials_budget": trials,
        "tolerance": tol,
        "cold_best_us": cold.best_latency_s * 1e6,
        "warm_best_us": warm.best_latency_s * 1e6,
        "target_us": target * 1e6,
        "cold_trials": cold.trials,
        "cold_trials_to_best": cold.trials_to_best,
        "warm_trials_to_target": warm_trials,
        "warm_frac_of_cold_trials": (
            warm_trials / cold.trials if warm_trials else None
        ),
        "meets_60pct": warm_trials is not None
        and warm_trials <= 0.6 * cold.trials,
    }
    if csv:
        frac = row["warm_frac_of_cold_trials"]
        print(
            f"tuning_time/{name}/warm_start,"
            f"{frac if frac is not None else 'inf'},"
            f"warm_trials={warm_trials};cold_trials={cold.trials};"
            f"meets_60pct={row['meets_60pct']}"
        )
    return row


def fleet_comparison(
    smoke: bool = False,
    backend: str = None,
    csv: bool = True,
    workers: int = 2,
) -> Optional[Dict]:
    """Fleet-vs-local tuning throughput on one workload, equal budgets.

    Spawns ``workers`` local measurement worker processes, tunes through
    an ``rpc://`` runner fanned out across them, and tunes the same
    workload with the in-process ``local`` runner.  Reports wall-clock per
    trial for both plus the fleet's per-worker dispatch telemetry, so the
    JSON artifact answers "what did distributing measurement buy?".
    """
    from repro.search.measure import spawn_local_workers

    trials = int(os.environ.get("REPRO_BENCH_TRIALS", "6" if smoke else "16"))
    name, kwargs, mxu = (SMOKE_WORKLOADS if smoke else WORKLOADS)[0]
    cfg = _bench_config(trials)
    local = tune_workload(
        name, kwargs,
        config=TuneConfig(search=cfg, use_mxu=mxu, backend=backend),
    )
    try:
        handles = spawn_local_workers(workers, backend=backend)
    except Exception as e:  # worker spawn is environment-sensitive: report
        if csv:
            print(f"tuning_time/{name}/fleet,skipped,{type(e).__name__}")
        return None
    rpc_stats: Dict = {}
    try:
        address = ",".join(f"{h.host}:{h.port}" for h in handles)
        runner = create_runner(f"rpc://{address}", backend=backend)
        try:
            fleet = tune_workload(
                name, kwargs,
                config=TuneConfig(search=cfg, use_mxu=mxu, runner_spec=runner),
            )
            rpc_stats = runner.stats()
        finally:
            runner.close()
    finally:
        for h in handles:
            h.kill()
    row = {
        "workload": name,
        "workers": workers,
        "trials_budget": trials,
        "local_trials": local.trials,
        "local_tuning_s": local.tuning_time_s,
        "local_s_per_trial": local.tuning_time_s / max(local.trials, 1),
        "fleet_trials": fleet.trials,
        "fleet_tuning_s": fleet.tuning_time_s,
        "fleet_s_per_trial": fleet.tuning_time_s / max(fleet.trials, 1),
        "speedup": local.tuning_time_s / max(fleet.tuning_time_s, 1e-9),
        "local_best_us": local.best_latency_s * 1e6,
        "fleet_best_us": fleet.best_latency_s * 1e6,
        "rpc": rpc_stats,
    }
    if csv:
        print(
            f"tuning_time/{name}/fleet,{row['speedup']:.2f},"
            f"workers={workers};local_s={local.tuning_time_s:.1f};"
            f"fleet_s={fleet.tuning_time_s:.1f};"
            f"worker_deaths={rpc_stats.get('worker_deaths', 0)}"
        )
    return row


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--smoke", action="store_true",
        help="single tiny workload + small trial budget (CI)",
    )
    ap.add_argument(
        "--runners", default=",".join(DEFAULT_RUNNERS),
        help="comma-separated runner registry specs to compare",
    )
    ap.add_argument(
        "--backend", default=None,
        help="lowering-backend spec (jnp, pallas, ...); default "
             "REPRO_BACKEND env or jnp",
    )
    ap.add_argument(
        "--json-out", default="BENCH_tuning_time.json",
        help="write rows + warm-start comparison to this JSON file "
             "('' disables)",
    )
    ap.add_argument(
        "--skip-warm", action="store_true",
        help="skip the cold-vs-warm learned-search comparison",
    )
    ap.add_argument(
        "--fleet", action="store_true",
        help="also compare rpc:// fleet measurement (spawned local "
             "workers) against the in-process local runner",
    )
    ap.add_argument(
        "--workers", type=int, default=2,
        help="fleet size for --fleet (default 2)",
    )
    args = ap.parse_args(argv)
    rows = run(
        smoke=args.smoke,
        runner_specs=[s for s in args.runners.split(",") if s],
        backend=args.backend,
    )
    warm = (
        None
        if args.skip_warm
        else warm_start_comparison(smoke=args.smoke, backend=args.backend)
    )
    fleet = (
        fleet_comparison(
            smoke=args.smoke, backend=args.backend, workers=args.workers
        )
        if args.fleet
        else None
    )
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(
                {"rows": rows, "warm_start": warm, "fleet": fleet},
                f, indent=2,
            )
        print(f"wrote {args.json_out}")


if __name__ == "__main__":
    main()
