"""Fold structured trace JSONL into a tuning diagnostics report.

Reads one or more trace files produced by running with ``REPRO_TRACE``
set (see :mod:`repro.obs.trace`), folds them with
:func:`repro.obs.report.fold`, prints the human-readable rendering, and
writes the machine-readable ``BENCH_tuning_report.json`` consumed by the
CI gate (``check_regression.py --report ... --min-dispatch-hit-rate``).

Usage::

    REPRO_TRACE=results/trace.jsonl python benchmarks/end_to_end.py
    python benchmarks/report.py results/trace.jsonl \
        [--json-out BENCH_tuning_report.json] [--top 10]
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.obs.report import fold, load_events, render_text  # noqa: E402

REPO_ROOT = Path(__file__).resolve().parents[1]
DEFAULT_JSON = REPO_ROOT / "BENCH_tuning_report.json"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "traces", nargs="+", help="trace JSONL file(s) to fold",
    )
    ap.add_argument(
        "--json-out", default=str(DEFAULT_JSON),
        help="machine-readable report path (default BENCH_tuning_report.json)",
    )
    ap.add_argument(
        "--top", type=int, default=10,
        help="how many slowest candidates to list",
    )
    args = ap.parse_args(argv)
    missing = [p for p in args.traces if not Path(p).exists()]
    if missing:
        print(f"FAIL: missing trace file(s): {', '.join(missing)}")
        return 1
    events = load_events(args.traces)
    if not events:
        print(f"FAIL: no events in {', '.join(args.traces)} — "
              "was the producer run with REPRO_TRACE set?")
        return 1
    report = fold(events, top_n=args.top)
    # write the artifact before printing: the report must survive a
    # consumer closing stdout early (e.g. piping through head)
    Path(args.json_out).write_text(json.dumps(report, indent=2) + "\n")
    print(render_text(report))
    print(f"wrote {args.json_out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
