"""Serving-router smoke gate: requests must survive a worker death.

Spawns 2 serving workers behind the :class:`repro.serving.router
.ServingRouter`, submits a batch of requests, kills one worker once the
run is in flight, and checks the failover contract end to end:

* every submitted request completes (the router resubmits a dead
  worker's unfinished requests to the survivor);
* the router actually observed the death (``worker_deaths >= 1``) and
  resubmitted at least one request;
* the survivor finished its share — and, without ``--kill-one``, both
  workers completed requests (least-loaded routing spreads load).

Every completed request's token stream is also checked against a
single-worker reference run of the same prompt (greedy decoding is
deterministic, so resubmission must not change results).  Results land
in ``BENCH_router_smoke.json``; any failed check exits nonzero, so CI
can gate on it.

    PYTHONPATH=src python benchmarks/router_smoke.py --kill-one
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List

MODEL = "smollm-135m"
MAX_SEQ = 32
MAX_SLOTS = 2
PREFILL_CHUNK = 4
PAGE_SIZE = 8


def _prompts(n: int) -> List[List[int]]:
    return [
        [(i * 13 + j) % 50 + 1 for j in range(1 + (i * 7) % 12)]
        for i in range(n)
    ]


def _reference_streams(prompts: List[List[int]], max_new: int) -> List[List[int]]:
    """Single-process greedy streams to compare the router's output to."""
    import jax
    import numpy as np

    from repro.configs.base import get_config
    from repro.models.registry import build_model
    from repro.serving import ContinuousBatchingScheduler, ServeConfig

    cfg = get_config(MODEL, smoke=True)
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    sched = ContinuousBatchingScheduler(
        cfg, params,
        config=ServeConfig(
            max_slots=MAX_SLOTS, max_seq=MAX_SEQ,
            page_size=PAGE_SIZE, prefill_chunk=PREFILL_CHUNK,
        ),
    )
    reqs = [
        sched.submit(np.asarray(p, np.int32), max_new_tokens=max_new)
        for p in prompts
    ]
    sched.run()
    return [list(r.generated) for r in reqs]


def run(workers: int = 2, requests: int = 8, max_new: int = 6,
        kill_one: bool = False) -> Dict:
    from repro.serving.router import ServingRouter

    checks: List[str] = []
    ok = True
    prompts = _prompts(requests)
    expected = _reference_streams(prompts, max_new)

    router = ServingRouter.spawn(
        workers, model=MODEL,
        max_slots=MAX_SLOTS, max_seq=MAX_SEQ,
        page_size=PAGE_SIZE, prefill_chunk=PREFILL_CHUNK,
    )
    try:
        t0 = time.perf_counter()
        for p in prompts:
            router.submit(p, max_new=max_new)
        if kill_one:
            # take a worker down while its requests are in flight; the
            # router must resubmit them to the survivor
            victim = router.workers[0]
            deadline = time.monotonic() + 60
            while (
                not router._outstanding[victim.index]
                and time.monotonic() < deadline
            ):
                router.poll()
                time.sleep(0.01)
            # kill only the process (not the router's link state) so the
            # router discovers the death through the broken connection
            victim.proc.kill()
            victim.proc.wait(timeout=10)
        router.drain(timeout_s=600)
        elapsed = time.perf_counter() - t0
        summary = router.summary()
    finally:
        router.shutdown()

    done = [r for r in router.requests if r.done]
    if len(done) != requests:
        checks.append(
            f"FAIL: {len(done)}/{requests} requests completed"
        )
        ok = False
    for r in router.requests:
        if r.done and r.tokens != expected[r.grid]:
            checks.append(
                f"FAIL: request {r.grid} stream diverged from the "
                f"single-worker reference (resubmits={r.resubmits})"
            )
            ok = False
    rstats = summary["router"]
    if kill_one:
        if rstats["worker_deaths"] < 1:
            checks.append("FAIL: --kill-one saw no worker death")
            ok = False
        if rstats["resubmits"] < 1:
            checks.append("FAIL: worker death triggered no resubmission")
            ok = False
        survivors = [w for w in summary["workers"] if w["alive"]]
        if not survivors or sum(w["completed"] for w in survivors) == 0:
            checks.append("FAIL: no survivor completed any request")
            ok = False
    elif workers >= 2:
        used = sum(1 for w in summary["workers"] if w["completed"] > 0)
        if used < 2:
            checks.append(
                f"FAIL: only {used}/{workers} workers completed requests"
            )
            ok = False

    return {
        "benchmark": "router_smoke",
        "ok": bool(ok),
        "checks_failed": checks,
        "workers": workers,
        "kill_one": kill_one,
        "requests": requests,
        "max_new": max_new,
        "elapsed_s": round(elapsed, 3),
        "summary": summary,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--requests", type=int,
                    default=int(os.environ.get("REPRO_BENCH_REQUESTS", "8")))
    ap.add_argument("--max-new", type=int, default=6)
    ap.add_argument("--kill-one", action="store_true",
                    help="kill one worker mid-run (failover-path check)")
    ap.add_argument("--json-out", default="BENCH_router_smoke.json")
    args = ap.parse_args(argv)
    row = run(workers=args.workers, requests=args.requests,
              max_new=args.max_new, kill_one=args.kill_one)
    print(json.dumps(row, indent=2))
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(row, f, indent=2)
        print(f"wrote {args.json_out}")
    if not row["ok"]:
        for c in row["checks_failed"]:
            print(c, file=sys.stderr)
        return 1
    print("router smoke OK: requests drained across workers")
    return 0


if __name__ == "__main__":
    sys.exit(main())
