"""Print a stable CI cache key for the tuned database.

The end-to-end jobs cache ``results/tuning_db*.json`` between runs
(``actions/cache``) so unchanged task sets skip re-tuning (the benchmark
honors ``REPRO_E2E_SKIP_TUNED=1``).  The cache key must change exactly
when the *tuning problem* changes, so it hashes:

* the structural hashes of every extracted task (same env knobs as
  ``end_to_end.py``: ``REPRO_E2E_MODELS`` / ``REPRO_E2E_SEQ`` /
  ``REPRO_E2E_TASKS`` / ``REPRO_E2E_OPS``) — any workload, shape,
  space, or extraction change reshuffles these;
* the lowering backend (``REPRO_BACKEND`` / ``--backend``) — a jnp-tuned
  record must never satisfy a pallas run.

Usage (CI)::

    KEY=$(PYTHONPATH=src python benchmarks/task_cache_key.py)
    # -> e.g. tuned-db-pallas-1a2b3c4d5e6f

Prints the key on stdout; everything else goes to stderr.
"""

import hashlib
import sys

from repro.backends.registry import resolve_backend_spec
from repro.configs.base import get_config
from repro.integration.extract import extract_task_specs


def cache_key(backend: str = None) -> str:
    # one env parser, shared with the benchmark itself: the cache key
    # must hash exactly the task set end_to_end.run() will tune
    try:
        from benchmarks.end_to_end import task_selection_env
    except ImportError:  # run as `python benchmarks/task_cache_key.py`
        from end_to_end import task_selection_env

    backend = resolve_backend_spec(backend)
    models, seq, max_tasks, ops = task_selection_env()
    h = hashlib.sha256()
    h.update(backend.encode())
    for arch in models:
        specs = extract_task_specs(
            get_config(arch), batch=1, seq=seq, max_tasks=max_tasks,
            ops=ops, dispatchable_only=True,
        )
        for s in specs:
            h.update(s.struct_hash.encode())
            print(f"  {arch}: {s.key} [{s.struct_hash[:12]}]", file=sys.stderr)
    return f"tuned-db-{backend}-{h.hexdigest()[:12]}"


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", default=None)
    args = ap.parse_args(argv)
    print(cache_key(backend=args.backend))
    return 0


if __name__ == "__main__":
    sys.exit(main())
