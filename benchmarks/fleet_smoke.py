"""Fleet smoke gate: rpc:// tuning must be equivalent to local tuning.

Spawns N local measurement workers, tunes one workload through an
``rpc://host:port,...`` runner, tunes the same workload with the serial
in-process ``local`` runner at the same seed and budget, and checks the
resulting database records are equivalent:

* both runs produce a best record under the **same workload key**;
* both best traces round-trip through JSON and re-validate against the
  workload (the record a later ``DispatchContext`` would serve);
* the fleet measured the full trial budget — nothing silently dropped —
  and (with ``--workers >= 2`` and no kill) spread batches over more than
  one worker.

``--kill-one`` kills a worker mid-run, checking the runner's
retry-on-worker-death path end to end: the run must still complete its
budget on the survivors and record a best.  Results (including the
runner's per-worker telemetry) land in ``BENCH_fleet_smoke.json``; any
failed check exits nonzero, so CI can gate on it.

    PYTHONPATH=src python benchmarks/fleet_smoke.py --workers 2 --kill-one
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import threading
from typing import Dict, List

from repro.core.modules import SpaceGenerator, default_modules
from repro.core.validator import validate_trace
from repro.core.workloads import get_workload
from repro.search.database import Database
from repro.search.evolutionary import SearchConfig
from repro.search.measure import create_runner, spawn_local_workers
from repro.search.tune import TuneConfig, tune_workload

WORKLOAD = ("gmm", dict(n=64, m=64, k=64))


def _tune(runner_spec, db: Database, trials: int) -> "TuneResult":  # noqa: F821
    cfg = TuneConfig(
        search=SearchConfig(
            max_trials=trials, init_random=max(trials // 2, 4),
            population=8, measure_per_round=max(trials // 2, 4), seed=0,
        ),
        runner_spec=runner_spec,
        warm_start=False,  # no sidecar coupling between the two runs
    )
    name, kwargs = WORKLOAD
    return tune_workload(name, kwargs, config=cfg, database=db)


def _best_record_ok(db: Database, key: str, checks: List[str]) -> bool:
    rec = db.best(key)
    if rec is None:
        checks.append(f"FAIL: no record for {key}")
        return False
    name, kwargs = WORKLOAD
    func = get_workload(name, **kwargs)
    from repro.core.trace import Trace

    v = validate_trace(func, Trace.from_json(rec.trace_json))
    if not v.ok:
        checks.append(f"FAIL: best record for {key} does not re-validate")
        return False
    return True


def run(workers: int = 2, kill_one: bool = False, trials: int = 8) -> Dict:
    backend = os.environ.get("REPRO_BACKEND")
    checks: List[str] = []
    ok = True

    local_db = Database(None)
    local = _tune(None, local_db, trials)

    handles = spawn_local_workers(workers, backend=backend)
    killed = threading.Event()
    try:
        address = ",".join(f"{h.host}:{h.port}" for h in handles)
        runner = create_runner(f"rpc://{address}", backend=backend)
        if kill_one:
            # take a worker down after the first measurements land — the
            # runner must reshard the round onto the survivors
            orig_run = runner.run

            def run_then_kill(inputs):
                res = orig_run(inputs)
                if not killed.is_set():
                    handles[0].kill()
                    killed.set()
                return res

            runner.run = run_then_kill
        fleet_db = Database(None)
        try:
            fleet = _tune(runner, fleet_db, trials)
            rpc_stats = runner.stats()
        finally:
            runner.close()
    finally:
        for h in handles:
            h.kill()

    key = local.workload_key
    if fleet.workload_key != key:
        checks.append(
            f"FAIL: workload keys differ: {key} vs {fleet.workload_key}"
        )
        ok = False
    ok &= _best_record_ok(local_db, key, checks)
    ok &= _best_record_ok(fleet_db, key, checks)
    if fleet.trials < trials:
        checks.append(
            f"FAIL: fleet measured {fleet.trials}/{trials} trials"
        )
        ok = False
    per_worker = rpc_stats.get("per_worker", {})
    used = sum(1 for w in per_worker.values() if w["candidates"] > 0)
    if kill_one:
        if rpc_stats.get("worker_deaths", 0) < 1:
            checks.append("FAIL: --kill-one saw no worker death")
            ok = False
        import math

        if not math.isfinite(fleet.best_latency_s):
            checks.append("FAIL: no finite best latency after worker death")
            ok = False
    elif workers >= 2 and used < 2:
        checks.append(
            f"FAIL: only {used}/{workers} workers received candidates"
        )
        ok = False

    return {
        "benchmark": "fleet_smoke",
        "ok": bool(ok),
        "checks_failed": checks,
        "workers": workers,
        "kill_one": kill_one,
        "trials_budget": trials,
        "workload_key": key,
        "local": {
            "trials": local.trials,
            "best_us": local.best_latency_s * 1e6,
            "tuning_s": round(local.tuning_time_s, 3),
            "records": len(local_db.records.get(key, [])),
        },
        "fleet": {
            "trials": fleet.trials,
            "best_us": fleet.best_latency_s * 1e6,
            "tuning_s": round(fleet.tuning_time_s, 3),
            "records": len(fleet_db.records.get(key, [])),
        },
        "rpc": rpc_stats,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--kill-one", action="store_true",
                    help="kill one worker mid-run (retry-path check)")
    ap.add_argument("--trials", type=int,
                    default=int(os.environ.get("REPRO_BENCH_TRIALS", "8")))
    ap.add_argument("--json-out", default="BENCH_fleet_smoke.json")
    args = ap.parse_args(argv)
    row = run(workers=args.workers, kill_one=args.kill_one,
              trials=args.trials)
    print(json.dumps(row, indent=2))
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(row, f, indent=2)
        print(f"wrote {args.json_out}")
    if not row["ok"]:
        for c in row["checks_failed"]:
            print(c, file=sys.stderr)
        return 1
    print("fleet smoke OK: rpc records equivalent to local")
    return 0


if __name__ == "__main__":
    sys.exit(main())
