"""CI benchmark regression gate over a fresh ``BENCH_end_to_end.json``.

Fails (exit 1) if any model's tuned/untuned speedup drops below the
floor — the search warm-starts from the untuned default schedule, so a
tuned forward slower than untuned means dispatch or measurement broke,
not that the search had an unlucky day.  ``--tolerance`` absorbs
wall-clock noise in small CI smoke runs (forward timings are medians of
a few repeats on shared runners).

Optionally also asserts dispatch coverage: ``--require-dispatched-op
attention`` fails unless at least one task of that op was actually
served — the Pallas backend job gates on the tuned fused-attention
workload, so the tentpole path can never silently regress to the
fixed-block default.  (It deliberately does *not* also require
``batch_matmul`` there: when fused attention serves, the whole call
bypasses the chunked score/value contractions — see the comment in
ci.yml.)  The flag repeats for jobs that do need several ops.

With ``--report BENCH_tuning_report.json`` (the output of
``benchmarks/report.py``) it can additionally gate on observed dispatch
coverage: ``--min-dispatch-hit-rate 0.05`` fails when the trace-derived
``mode="best"`` hit rate drops below the floor — a broken dispatch path
shows up here even when forward timings stay plausible.

With ``--serving`` the gate instead reads a ``BENCH_serving.json``
(``benchmarks/serving_load.py`` output): the tuned/untuned decode tok/s
ratio must clear ``--min-decode-ratio`` (after ``--tolerance``), the
run must have actually dispatched at least one decode-shape attention
task *and* one decode-shape dense/batch_matmul task — decode dispatch
silently regressing to the reference path would leave throughput
plausible but untuned — and, when the payload carries a saturation
sweep, the paged serving tier must sustain strictly greater tok/s than
the slot-pool baseline at the highest swept arrival rate
(``--require-sweep`` makes a missing sweep itself a failure).

Usage::

    python benchmarks/check_regression.py [BENCH_end_to_end.json]
        [--min-speedup 1.0] [--tolerance 0.05]
        [--require-dispatched-op attention]
        [--require-dispatched-op batch_matmul]
        [--report BENCH_tuning_report.json --min-dispatch-hit-rate 0.05]
    python benchmarks/check_regression.py BENCH_serving.json --serving
        [--min-decode-ratio 1.0] [--tolerance 0.05]
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

DEFAULT_JSON = Path(__file__).resolve().parents[1] / "BENCH_end_to_end.json"


def check_report(path: Path, min_dispatch_hit_rate: float) -> "list[str]":
    """Gate on a folded tuning report; returns failure messages."""
    report = json.loads(Path(path).read_text())
    dispatch = report.get("dispatch", {})
    rate = dispatch.get("hit_rate")
    if rate is None:
        return [
            f"{path}: no mode='best' dispatch events in the report — "
            "cannot assert the hit-rate floor"
        ]
    status = "ok" if rate >= min_dispatch_hit_rate else "REGRESSION"
    print(
        f"dispatch hit_rate(best)={rate:.3f} "
        f"(floor {min_dispatch_hit_rate:.3f}, hits={dispatch.get('hits')}, "
        f"misses={dispatch.get('misses')}) [{status}]"
    )
    if rate < min_dispatch_hit_rate:
        return [
            f"dispatch hit rate {rate:.3f} < floor {min_dispatch_hit_rate:.3f}"
        ]
    return []


def check_serving(
    path: Path,
    min_decode_ratio: float = 1.0,
    tolerance: float = 0.05,
    require_sweep: bool = False,
) -> int:
    """Gate a ``serving_load.py`` payload: decode throughput ratio,
    decode-shape dispatch coverage (attention AND dense/bmm), and the
    paged-vs-slot-pool saturation sweep at the highest swept rate."""
    payload = json.loads(Path(path).read_text())
    failures = []
    ratio = float(payload.get("decode_ratio", 0.0))
    floor = min_decode_ratio * (1.0 - tolerance)
    status = "ok" if ratio >= floor else "REGRESSION"
    print(
        f"{payload.get('model', '?')}: decode tuned/untuned="
        f"{ratio:.3f}x (floor {floor:.3f}x, "
        f"tuned={payload.get('tuned', {}).get('decode_tok_s')} tok/s, "
        f"untuned={payload.get('untuned', {}).get('decode_tok_s')} tok/s) "
        f"[{status}]"
    )
    if ratio < floor:
        failures.append(f"decode tok/s ratio {ratio:.3f}x < floor {floor:.3f}x")
    keys = payload.get("decode_dispatch_keys", [])
    ops = {k.split("/", 1)[0] for k in keys}
    print(f"decode dispatch keys: {len(keys)} ({', '.join(sorted(ops)) or 'none'})")
    if "attention_decode" not in ops:
        failures.append(
            "no decode-shape attention task dispatched "
            f"(keys: {keys or 'none'})"
        )
    if not ops & {"dense", "batch_matmul"}:
        failures.append(
            "no decode-shape dense/batch_matmul task dispatched "
            f"(keys: {keys or 'none'})"
        )
    sweep = payload.get("sweep") or []
    if not sweep:
        msg = "no saturation sweep in payload"
        if require_sweep:
            failures.append(msg)
        else:
            print(f"{msg} (not required)")
    else:
        top = max(sweep, key=lambda r: r.get("rate_req_s", 0.0))
        paged = (top.get("paged") or {}).get("tok_s")
        base = (top.get("slot_pool") or {}).get("tok_s")
        rate = top.get("rate_req_s")
        if paged is None or base is None:
            failures.append(
                f"sweep row at rate {rate} lacks paged/slot_pool tok_s"
            )
        else:
            status = "ok" if paged > base else "REGRESSION"
            print(
                f"sweep@{rate:g} req/s: paged={paged} tok/s vs "
                f"slot_pool={base} tok/s [{status}]"
            )
            if not paged > base:
                failures.append(
                    f"paged tier {paged} tok/s not strictly greater than "
                    f"slot-pool baseline {base} tok/s at {rate:g} req/s"
                )
    if failures:
        print("FAIL:\n  " + "\n  ".join(failures))
        return 1
    print("serving gate passed")
    return 0


def check(
    path: Path,
    min_speedup: float = 1.0,
    tolerance: float = 0.05,
    require_dispatched_op: "str | list" = "",
    report: str = "",
    min_dispatch_hit_rate: float = 0.0,
) -> int:
    required_ops = (
        [require_dispatched_op]
        if isinstance(require_dispatched_op, str) and require_dispatched_op
        else list(require_dispatched_op or [])
    )
    payload = json.loads(Path(path).read_text())
    models = payload.get("models", [])
    if not models:
        print(f"FAIL: {path} holds no model rows")
        return 1
    floor = min_speedup * (1.0 - tolerance)
    failures = []
    for row in models:
        name = row.get("model", "?")
        speedup = float(row.get("speedup", 0.0))
        status = "ok" if speedup >= floor else "REGRESSION"
        print(
            f"{name}: speedup={speedup:.3f}x (floor {floor:.3f}x, "
            f"backend={row.get('backend', payload.get('backend', '?'))}) "
            f"[{status}]"
        )
        if speedup < floor:
            failures.append(
                f"{name}: tuned/untuned speedup {speedup:.3f}x < {floor:.3f}x"
            )
        for op in required_ops:
            served = [
                t for t in row.get("tasks", [])
                if t.get("op") == op and t.get("dispatched")
            ]
            present = [
                t for t in row.get("tasks", []) if t.get("op") == op
            ]
            print(f"{name}: {op} tasks dispatched {len(served)}/{len(present)}")
            if not served:
                failures.append(
                    f"{name}: no {op!r} task was dispatched "
                    f"(extracted: {len(present)})"
                )
    if report:
        failures.extend(check_report(Path(report), min_dispatch_hit_rate))
    if failures:
        print("FAIL:\n  " + "\n  ".join(failures))
        return 1
    print("benchmark gate passed")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("json_path", nargs="?", default=str(DEFAULT_JSON))
    ap.add_argument("--min-speedup", type=float, default=1.0)
    ap.add_argument(
        "--tolerance", type=float, default=0.05,
        help="relative wall-clock noise allowance on the floor",
    )
    ap.add_argument(
        "--require-dispatched-op", action="append", default=[],
        help="fail unless >=1 task of this op was dispatched (e.g. "
             "batch_matmul); repeat the flag for several ops",
    )
    ap.add_argument(
        "--report", default="",
        help="folded tuning report (benchmarks/report.py output) to gate "
             "dispatch coverage against",
    )
    ap.add_argument(
        "--min-dispatch-hit-rate", type=float, default=0.0,
        help="floor on the report's mode='best' dispatch hit rate "
             "(requires --report)",
    )
    ap.add_argument(
        "--serving", action="store_true",
        help="treat json_path as BENCH_serving.json and gate the decode "
             "ratio + decode dispatch coverage instead",
    )
    ap.add_argument(
        "--min-decode-ratio", type=float, default=1.0,
        help="floor on tuned/untuned decode tok/s (with --serving)",
    )
    ap.add_argument(
        "--require-sweep", action="store_true",
        help="with --serving, fail if the payload has no saturation sweep",
    )
    args = ap.parse_args(argv)
    if args.serving:
        rc = check_serving(
            Path(args.json_path),
            min_decode_ratio=args.min_decode_ratio,
            tolerance=args.tolerance,
            require_sweep=args.require_sweep,
        )
        if args.report:
            msgs = check_report(Path(args.report), args.min_dispatch_hit_rate)
            if msgs:
                print("FAIL:\n  " + "\n  ".join(msgs))
                rc = rc or 1
        return rc
    return check(
        Path(args.json_path),
        min_speedup=args.min_speedup,
        tolerance=args.tolerance,
        require_dispatched_op=args.require_dispatched_op,
        report=args.report,
        min_dispatch_hit_rate=args.min_dispatch_hit_rate,
    )


if __name__ == "__main__":
    sys.exit(main())
