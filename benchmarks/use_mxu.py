"""Figure 10b: hardware-specific module value — Use-MXU on a BERT-style
fused dense (the paper reports 48% speedup from Use-Tensor-Core).

Same budget with and without the UseMXU module composed into the space.
"""

from __future__ import annotations

import os
from typing import Dict

from repro.core.modules import default_modules
from repro.search.evolutionary import SearchConfig
from repro.search.tune import tune_workload

SHAPE = dict(m=128, n=1024, k=256)  # BERT-large-ish ffn tile, CPU-scaled


def run(csv: bool = True) -> Dict:
    trials = int(os.environ.get("REPRO_BENCH_TRIALS", "24"))
    cfg = SearchConfig(
        max_trials=trials,
        init_random=max(trials // 4, 4),
        population=max(trials // 2, 8),
        measure_per_round=max(trials // 4, 4),
    )
    base = tune_workload(
        "fused_dense", SHAPE, modules=default_modules(use_mxu=False), config=cfg
    )
    mxu = tune_workload(
        "fused_dense", SHAPE, modules=default_modules(use_mxu=True), config=cfg
    )
    speedup = base.best_latency_s / mxu.best_latency_s
    out = {
        "generic_us": base.best_latency_s * 1e6,
        "use_mxu_us": mxu.best_latency_s * 1e6,
        "speedup_pct": (speedup - 1) * 100,
    }
    if csv:
        print(
            f"use_mxu/fused_dense,{out['use_mxu_us']:.2f},"
            f"generic={out['generic_us']:.2f};gain={out['speedup_pct']:.1f}%"
        )
    return out


if __name__ == "__main__":
    run()
