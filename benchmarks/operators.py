"""Figure 8: operator/subgraph performance — MetaSchedule-tuned vs the
naive-jnp (XLA) lowering of the same tensor program.

The paper's 12 Appendix-A.2 workloads.  Shapes follow A.2 except the conv
monsters (C2D/C3D/DIL/CBR), which are scaled so a CPU tuning run finishes
in minutes; the tuned-vs-baseline comparison semantics is unchanged.
Set REPRO_BENCH_TRIALS to scale search effort.
"""

from __future__ import annotations

import os
from typing import Dict, List

from repro.search.database import Database
from repro.search.evolutionary import SearchConfig
from repro.search.tune import tune_workload

# (workload, shape kwargs, use_mxu)
BENCH_OPS = [
    ("c1d", dict(), False),
    ("c2d", dict(h=56, w=56, cin=3, cout=16, ksize=7, stride=2, pad=3), False),
    ("c3d", dict(d=8, h=28, w=28, cin=3, cout=8, ksize=3, stride=1, pad=1), False),
    ("dep", dict(h=56, w=56, c=32), False),
    ("dil", dict(h=56, w=56, cin=3, cout=16, ksize=3, stride=1, pad=2, dilation=2), False),
    ("gmm", dict(n=128, m=128, k=128), True),
    ("grp", dict(h=28, w=28, cin=32, cout=32, groups=4, ksize=3, stride=1, pad=1), False),
    ("t2d", dict(h=4, w=4, cin=64, cout=32), False),
    ("cbr", dict(h=56, w=56, cin=3, cout=16, ksize=7, stride=2, pad=3), False),
    ("tbg", dict(seq=128, head=12, dim=64), True),
    ("nrm", dict(m=256, n=256), False),
    ("sfm", dict(m=256, n=256), False),
]


def _config() -> SearchConfig:
    trials = int(os.environ.get("REPRO_BENCH_TRIALS", "24"))
    return SearchConfig(
        max_trials=trials,
        init_random=max(trials // 4, 4),
        population=max(trials // 2, 8),
        measure_per_round=max(trials // 4, 4),
        generations=3,
    )


def run(db_path: str = "results/tuning_db.json", csv: bool = True) -> List[Dict]:
    db = Database(db_path)
    out = []
    for name, kwargs, mxu in BENCH_OPS:
        res = tune_workload(
            name, kwargs, use_mxu=mxu, config=_config(), database=db
        )
        row = {
            "op": name,
            "tuned_us": res.best_latency_s * 1e6,
            "default_us": res.default_latency_s * 1e6,
            "xla_us": res.baseline_latency_s * 1e6,
            "speedup_vs_default": res.speedup_vs_default,
            "speedup_vs_xla": res.speedup_vs_baseline,
            "trials": res.trials,
            "tuning_s": res.tuning_time_s,
        }
        out.append(row)
        if csv:
            print(
                f"operators/{name},{row['tuned_us']:.2f},"
                f"default={row['default_us']:.2f};xla={row['xla_us']:.2f};"
                f"speedup_vs_default={row['speedup_vs_default']:.2f}x"
            )
    return out


if __name__ == "__main__":
    run()
